"""Kernel-level benchmarks.

``--mode bucket`` (default, the original benchmark): the fused SCE
in-bucket kernel vs the materializing jnp path — analytic HBM traffic
(the quantity the fusion eliminates) plus CPU-interpret wall time as a
correctness-path check.

``--mode sce-pipeline``: the full SCE loss pipeline staged as
selection / gather / loss, dense vs fused, per stage:

  * selection — dense ``B @ Yᵀ`` + ``lax.top_k`` vs the streaming
    ``kernels.ops.mips_topk`` (no ``(n_b, C)`` score matrix);
  * gather+loss — materialized ``Y[idx_y]`` + jnp bucket CE vs the
    scalar-prefetch ``kernels.ops.sce_gather_loss`` (no
    ``(n_b, b_y, d)`` candidate tensor, dY straight into ``(C, d)``).

Each row reports wall time AND the analytic peak loss-side elements
from ``core.sce.sce_peak_elements`` — on CPU the kernels run in
interpret mode, so the element columns are the structural result and
the times are a correctness-path check, not TPU numbers. ``--json``
dumps the rows (CI emits ``BENCH_sce_pipeline.json`` at small shape so
the perf trajectory accumulates as build artifacts).

On TPU, the fused paths' win is structural: the (n_b, C) selection
scores, (n_b, b_x, b_y) logit tensor and (n_b, b_y, d) gather never
round-trip HBM.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.sce import SCEConfig, sce_peak_elements
from repro.kernels import ops, ref


def traffic_model(n_b, b_x, b_y, d, bytes_per=4):
    tiles = n_b * (b_x * d + b_y * d) * bytes_per  # operand reads
    logits = n_b * b_x * b_y * bytes_per  # materialized tensor
    return {
        "jnp_path_bytes": tiles + 2 * logits,  # write + read back
        "fused_bytes": tiles + n_b * b_x * bytes_per * 2,  # loss+lse only
    }


def _timeit(f, *args, reps=3):
    f(*args).block_until_ready()  # compile + warm
    t0 = time.time()
    for _ in range(reps):
        f(*args).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run_bucket():
    shapes = [(8, 128, 256, 64), (16, 256, 512, 64), (4, 362, 1024, 128)]
    rows = []
    for n_b, b_x, b_y, d in shapes:
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        x_b = jax.random.normal(ks[0], (n_b, b_x, d))
        y_b = jax.random.normal(ks[1], (n_b, b_y, d))
        tgt = jax.random.randint(ks[2], (n_b, b_x), 0, 10_000)
        cand = jax.random.randint(ks[3], (n_b, b_y), 0, 10_000)
        pos = jax.random.normal(ks[4], (n_b, b_x))

        f_fused = jax.jit(
            lambda *a: ops.sce_bucket_loss(*a, interpret=True)
        )
        f_ref = jax.jit(ref.sce_bucket_loss_ref)
        args = (x_b, y_b, tgt, cand, pos)
        tm = traffic_model(n_b, b_x, b_y, d)
        rows.append({
            "shape": f"{n_b}x{b_x}x{b_y}x{d}",
            "jnp_us": _timeit(f_ref, *args),
            "fused_interp_us": _timeit(f_fused, *args),
            "hbm_saved_mib": (tm["jnp_path_bytes"] - tm["fused_bytes"])
            / 2**20,
        })
    derived = (
        f"fusion saves {rows[-1]['hbm_saved_mib']:.0f} MiB HBM traffic "
        f"per pass at the LM shape (structural; interpret-mode times are "
        f"not TPU times)"
    )
    return rows, derived


def run_sce_pipeline(n=512, c=2048, d=32, n_b=16, b_x=32, b_y=64):
    """Stage-by-stage dense vs fused timing + analytic peak elements."""
    cfg = SCEConfig(n_b, b_x, b_y, use_mix=False)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (n, d))
    y = jax.random.normal(ks[1], (c, d))
    t = jax.random.randint(ks[2], (n,), 0, c)
    b = jax.random.normal(ks[3], (n_b, d))

    # -- selection stage ---------------------------------------------------
    def sel_dense(b, y):
        _, idx = jax.lax.top_k(b @ y.T, b_y)
        return idx

    def sel_fused(b, y):
        _, idx = ops.mips_topk(b, y, b_y, interpret=True)
        return idx

    sel_dense_us = _timeit(jax.jit(sel_dense), b, y)
    sel_fused_us = _timeit(jax.jit(sel_fused), b, y)
    idx_y = jax.jit(sel_dense)(b, y)
    _, idx_x = jax.lax.top_k(b @ x.T, b_x)
    x_b = jnp.take(x, idx_x, axis=0)
    tgt_b = jnp.take(t, idx_x, axis=0)
    pos = jnp.einsum("nxd,nxd->nx", x_b, jnp.take(y, tgt_b, axis=0))

    # -- gather + loss stage -----------------------------------------------
    def gl_dense(x_b, y, pos):
        y_b = jnp.take(y, idx_y, axis=0)
        return ref.sce_bucket_loss_ref(x_b, y_b, tgt_b, idx_y, pos)

    def gl_fused(x_b, y, pos):
        return ops.sce_gather_loss(
            x_b, y, idx_y, tgt_b, idx_y, pos, interpret=True
        )

    gl_dense_us = _timeit(jax.jit(gl_dense), x_b, y, pos)
    gl_fused_us = _timeit(jax.jit(gl_fused), x_b, y, pos)

    elems = {
        p: sce_peak_elements(cfg, n, c, d, fused=f)
        for p, f in (("dense", False), ("fused", True))
    }
    rows = [{
        "shape": f"N={n} C={c} d={d} nb={n_b} bx={b_x} by={b_y}",
        "stage": stage,
        "dense_us": du,
        "fused_interp_us": fu,
        "dense_peak_elems": de,
        "fused_peak_elems": fe,
    } for stage, du, fu, de, fe in [
        ("selection", sel_dense_us, sel_fused_us,
         elems["dense"]["selection_scores"],
         elems["fused"]["selection_scores"]),
        # gather has no standalone timing: dense folds it into the loss
        # jit and fused never materializes it — analytic elements only.
        ("gather", None, None,
         elems["dense"]["candidate_embeddings"]
         + elems["dense"]["candidate_grads"],
         elems["fused"]["candidate_embeddings"]),
        ("loss", gl_dense_us, gl_fused_us,
         elems["dense"]["bucket_logits"], elems["fused"]["bucket_logits"]),
        ("total", sel_dense_us + gl_dense_us, sel_fused_us + gl_fused_us,
         elems["dense"]["total"], elems["fused"]["total"]),
    ]]
    derived = (
        f"fused pipeline peak {elems['dense']['total']/elems['fused']['total']:.0f}x "
        f"smaller than dense (elements; interpret-mode times are not TPU "
        f"times)"
    )
    return rows, derived


def run():
    return run_bucket()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("bucket", "sce-pipeline"),
                    default="bucket")
    ap.add_argument("--json", help="write rows + derived summary to PATH")
    ap.add_argument("--catalog", type=int, default=2048,
                    help="sce-pipeline catalog size")
    ap.add_argument("--positions", type=int, default=512,
                    help="sce-pipeline position count")
    args = ap.parse_args()
    if args.mode == "sce-pipeline":
        rows, derived = run_sce_pipeline(n=args.positions, c=args.catalog)
        cols = ("stage", "dense_us", "fused_interp_us",
                "dense_peak_elems", "fused_peak_elems")
        print(",".join(cols))
        for r in rows:
            du = "-" if r["dense_us"] is None else f"{r['dense_us']:.0f}"
            fu = ("-" if r["fused_interp_us"] is None
                  else f"{r['fused_interp_us']:.0f}")
            print(f"{r['stage']},{du},{fu},{r['dense_peak_elems']},"
                  f"{r['fused_peak_elems']}")
    else:
        rows, derived = run()
        print("shape,jnp_us,fused_interp_us,hbm_saved_mib")
        for r in rows:
            print(f"{r['shape']},{r['jnp_us']:.0f},"
                  f"{r['fused_interp_us']:.0f},{r['hbm_saved_mib']:.1f}")
    print(derived)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"mode": args.mode, "rows": rows, "derived": derived},
                      f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
