"""Paper Fig. 5 — peak loss-memory vs catalog size for
CE / BCE⁺ / gBCE / CE⁻ / SCE (batch 64, 256 negatives, as in the paper).

Reproduces the paper's two findings:
  * below ~40K items, negative-sampling losses cost MORE than full CE
    (the gathered negative-embedding term dominates);
  * SCE stays cheapest at every catalog size.
"""
from __future__ import annotations

from repro.core.losses import loss_peak_elements
from repro.core.sce import SCEConfig

MiB = 2**20
CATALOGS = [3_000, 22_307, 32_434, 96_830, 137_039, 173_511, 1_000_000]
BATCH, SEQ, D, NEGS = 64, 200, 64, 256


def run():
    n_pos = BATCH * SEQ
    rows = []
    for c in CATALOGS:
        sce_cfg = SCEConfig.from_alpha_beta(n_pos, c, bucket_size_y=NEGS)
        row = {"catalog": c}
        for loss in ("ce", "bce_plus", "gbce", "ce_minus", "sce"):
            elems = loss_peak_elements(
                loss, n_pos, c, D, num_negatives=NEGS, cfg=sce_cfg
            )
            row[loss] = elems * 4 / MiB
        rows.append(row)
    # paper claims: CE < BCE+ for small catalogs; SCE smallest everywhere
    small = rows[0]
    derived = (
        f"small_catalog_ce_vs_bce={small['ce']/small['bce_plus']:.2f} "
        f"(paper: <1 below 40K items); "
        f"sce_vs_ce_at_1M={rows[-1]['ce']/rows[-1]['sce']:.0f}x"
    )
    return rows, derived


def main():
    rows, derived = run()
    print("catalog,ce_mib,bce_plus_mib,gbce_mib,ce_minus_mib,sce_mib")
    for r in rows:
        print(f"{r['catalog']},{r['ce']:.1f},{r['bce_plus']:.1f},"
              f"{r['gbce']:.1f},{r['ce_minus']:.1f},{r['sce']:.1f}")
    print(derived)


if __name__ == "__main__":
    main()
