"""Paper Fig. 4a/4b + Table 2 — the Mix (bucket-collapse mitigation)
ablation: unique-selection fraction and correct-class-logit fraction over
training, and final quality with vs without Mix.
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import train_sasrec
from repro.core.sce import SCEConfig

N_ITEMS, BATCH, SEQ = 2000, 32, 50


def run(steps: int = 120):
    n_pos = BATCH * SEQ
    out = {}
    for use_mix in (False, True):
        cfg = SCEConfig.from_alpha_beta(
            n_pos, N_ITEMS, bucket_size_y=128, use_mix=use_mix
        )
        res = train_sasrec(
            loss_name="sce", sce_cfg=cfg, n_items=N_ITEMS, batch=BATCH,
            seq_len=SEQ, steps=steps, collect_aux=True,
        )
        hist = res.aux_history or []
        out[use_mix] = {
            "ndcg@10": res.metrics["ndcg@10"],
            "hr@10": res.metrics["hr@10"],
            "cov@10": res.metrics["cov@10"],
            "mean_unique_frac": float(np.mean(
                [h["unique_selection_frac"] for h in hist]
            )),
            "mean_correct_frac": float(np.mean(
                [h["correct_class_logit_frac"] for h in hist]
            )),
            "final_unique_frac": hist[-1]["unique_selection_frac"],
        }
    derived = (
        f"unique_frac mix={out[True]['mean_unique_frac']:.3f} vs "
        f"nomix={out[False]['mean_unique_frac']:.3f}; "
        f"ndcg@10 mix={out[True]['ndcg@10']:.4f} vs "
        f"nomix={out[False]['ndcg@10']:.4f}"
    )
    return out, derived


def main():
    out, derived = run()
    print("mix,ndcg@10,hr@10,cov@10,mean_unique_frac,mean_correct_frac")
    for mix in (False, True):
        r = out[mix]
        print(f"{mix},{r['ndcg@10']:.4f},{r['hr@10']:.4f},"
              f"{r['cov@10']:.4f},{r['mean_unique_frac']:.4f},"
              f"{r['mean_correct_frac']:.4f}")
    print(derived)


if __name__ == "__main__":
    main()
