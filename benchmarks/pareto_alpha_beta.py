"""Paper Fig. 3 — effect of the (α, β) parametrization on the
quality-vs-memory Pareto front (synthetic catalog, reduced grid).

For each (α, β) we sweep b_y and record (loss-memory, NDCG@10); the
paper's finding to reproduce: fronts for α ∈ {2,4} × β ∈ {1,4} land on
approximately the same optimal frontier, so α=2, β=1 is a safe default.

(The multi-LOSS Pareto — SCE vs RECE vs blockwise CE vs the sampled
family at catalogs up to 10M — lives in ``benchmarks/pareto_losses.py``;
this file sweeps SCE's own hyperparameters.)

CLI: ``--steps N`` for smoke runs, ``--json PATH`` for the
schema-pinned ``BENCH_pareto_ab.json`` artifact — the same contract as
every other bench. ``peak_elems_vs_naive`` (analytic, machine
independent) is the column ``benchmarks/trajectory.py`` gates.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.harness import train_sasrec
from repro.core.losses import loss_peak_elements
from repro.core.sce import SCEConfig

N_ITEMS, BATCH, SEQ = 2000, 32, 50
GRID_ALPHA = (1.0, 2.0, 4.0)
GRID_BETA = (1.0, 4.0)
GRID_BY = (32, 128)


def run(steps: int = 100):
    n_pos = BATCH * SEQ
    naive = loss_peak_elements("ce", n_pos, N_ITEMS, 48)
    rows = []
    for alpha in GRID_ALPHA:
        for beta in GRID_BETA:
            for b_y in GRID_BY:
                cfg = SCEConfig.from_alpha_beta(
                    n_pos, N_ITEMS, alpha=alpha, beta=beta,
                    bucket_size_y=b_y,
                )
                res = train_sasrec(
                    loss_name="sce", sce_cfg=cfg, n_items=N_ITEMS,
                    batch=BATCH, seq_len=SEQ, steps=steps,
                )
                rows.append({
                    "label": f"a{alpha:g}_b{beta:g}_y{b_y}",
                    "alpha": alpha, "beta": beta, "b_y": b_y,
                    "mem_elems": res.loss_peak_elements,
                    "peak_elems_vs_naive":
                        res.loss_peak_elements / naive,
                    "ndcg@10": res.metrics["ndcg@10"],
                })
    best_default = max(
        (r for r in rows if r["alpha"] == 2.0 and r["beta"] == 1.0),
        key=lambda r: r["ndcg@10"],
    )
    best_any = max(rows, key=lambda r: r["ndcg@10"])
    derived = (
        f"best(alpha=2,beta=1) ndcg={best_default['ndcg@10']:.4f}; "
        f"best overall ndcg={best_any['ndcg@10']:.4f} at "
        f"a={best_any['alpha']},b={best_any['beta']}"
    )
    return rows, derived


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--json", help="write rows + derived summary to PATH")
    args = ap.parse_args()
    rows, derived = run(steps=args.steps)
    print("alpha,beta,b_y,mem_elems,peak_elems_vs_naive,ndcg@10")
    for r in rows:
        print(f"{r['alpha']},{r['beta']},{r['b_y']},{r['mem_elems']},"
              f"{r['peak_elems_vs_naive']:.4f},{r['ndcg@10']:.4f}")
    print(derived)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"mode": "pareto-alpha-beta", "steps": args.steps,
                 "rows": rows, "derived": derived},
                f, indent=2,
            )
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
