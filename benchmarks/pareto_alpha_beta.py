"""Paper Fig. 3 — effect of the (α, β) parametrization on the
quality-vs-memory Pareto front (synthetic catalog, reduced grid).

For each (α, β) we sweep b_y and record (loss-memory, NDCG@10); the
paper's finding to reproduce: fronts for α ∈ {2,4} × β ∈ {1,4} land on
approximately the same optimal frontier, so α=2, β=1 is a safe default.
"""
from __future__ import annotations

from benchmarks.harness import train_sasrec
from repro.core.sce import SCEConfig

N_ITEMS, BATCH, SEQ = 2000, 32, 50
GRID_ALPHA = (1.0, 2.0, 4.0)
GRID_BETA = (1.0, 4.0)
GRID_BY = (32, 128)


def run(steps: int = 100):
    n_pos = BATCH * SEQ
    rows = []
    for alpha in GRID_ALPHA:
        for beta in GRID_BETA:
            for b_y in GRID_BY:
                cfg = SCEConfig.from_alpha_beta(
                    n_pos, N_ITEMS, alpha=alpha, beta=beta,
                    bucket_size_y=b_y,
                )
                res = train_sasrec(
                    loss_name="sce", sce_cfg=cfg, n_items=N_ITEMS,
                    batch=BATCH, seq_len=SEQ, steps=steps,
                )
                rows.append({
                    "alpha": alpha, "beta": beta, "b_y": b_y,
                    "mem_elems": res.loss_peak_elements,
                    "ndcg@10": res.metrics["ndcg@10"],
                })
    best_default = max(
        (r for r in rows if r["alpha"] == 2.0 and r["beta"] == 1.0),
        key=lambda r: r["ndcg@10"],
    )
    best_any = max(rows, key=lambda r: r["ndcg@10"])
    derived = (
        f"best(alpha=2,beta=1) ndcg={best_default['ndcg@10']:.4f}; "
        f"best overall ndcg={best_any['ndcg@10']:.4f} at "
        f"a={best_any['alpha']},b={best_any['beta']}"
    )
    return rows, derived


def main():
    rows, derived = run()
    print("alpha,beta,b_y,mem_elems,ndcg@10")
    for r in rows:
        print(f"{r['alpha']},{r['beta']},{r['b_y']},{r['mem_elems']},"
              f"{r['ndcg@10']:.4f}")
    print(derived)


if __name__ == "__main__":
    main()
