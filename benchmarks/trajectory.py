"""Benchmark trajectory check: current BENCH_*.json vs committed baselines.

CI emits ``BENCH_*.json`` artifacts every run (smoke-scale — small
shapes, CPU), but artifacts alone don't FAIL anything: a schema change
or a structural regression only shows up when a human diffs two runs.
This module turns the committed snapshot under ``benchmarks/baselines/``
into a gate:

  * **schema drift** — a current file whose top-level keys, ``mode``,
    or per-row key sets differ from its baseline fails (downstream
    consumers of the artifacts — the schema tests, plot scripts — key
    on those names);
  * **metric regression** — machine-independent RATIO metrics
    (``tokens_per_s_vs_naive``, ``peak_elems_vs_naive``,
    ``flop_ratio_vs_twopass``, the fused/dense peak-element quotient)
    fail if they move in the BAD direction by more than 25%. Raw wall
    times are machine-dependent and are deliberately NOT compared —
    a slower CI runner must not fail the build, a fusion that stops
    fusing must.

Usage::

    python -m benchmarks.trajectory --current . \
        --baselines benchmarks/baselines          # check (CI)
    python -m benchmarks.trajectory --current . \
        --baselines benchmarks/baselines --update # snapshot new baselines

A current file with no committed baseline is reported but does not
fail (the first CI run after adding a bench mode passes; commit the
snapshot via ``--update`` to start gating it). A MISSING current file
that has a baseline fails — a bench silently dropping out of CI is
exactly the kind of drift this exists to catch.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys

THRESHOLD = 0.25  # relative bad-direction movement that fails

# metric name -> True if higher is better
_RATIO_METRICS = {
    "tokens_per_s_vs_naive": True,
    "peak_elems_vs_naive": False,
    "flop_ratio_vs_twopass": False,
    # serve mode: jit cache misses on the request path. Machine
    # independent (a count, target 0); gated by the zero-baseline rule
    # in compare() — any recompile showing up in CI is a hard fail.
    "recompiles": False,
    # ckpt mode: restores that bypassed manifest verification. The
    # fallback ladder must NEVER load unverified bytes — zero-baseline
    # gated, so a regression that sneaks a verify=False load into the
    # restore path fails CI structurally, not statistically.
    "unverified_loads": False,
    # guard mode (kernels/guard): all four are zero-baseline gated.
    # A kernel failing its conformance canaries, a preflight config
    # escaping as an uncaught exception, a seeded non-finite the
    # sentinels miss, or a sentinel tripping on a healthy loss is a
    # structural regression, not noise.
    "canary_failures": False,
    "preflight_uncaught": False,
    "sentinel_misses": False,
    "sentinel_false_positives": False,
}


def _row_label(row, i):
    # An explicit "label" wins — benches whose rows aren't unique under
    # a single key (e.g. pareto rows: same loss at several catalog
    # sizes) emit one so metrics don't collide across rows.
    if "label" in row:
        return str(row["label"])
    if "protocol" in row:
        return f"{row['protocol']}/{row.get('path', '')}/{row.get('stage', '')}"
    for k in ("loss", "stage", "shape", "metric", "bucket"):
        if k in row:
            return str(row[k])
    return str(i)


def extract_metrics(payload):
    """``label.metric -> (value, higher_is_better)`` for every
    machine-independent ratio metric present in the rows."""
    out = {}
    for i, row in enumerate(payload.get("rows", [])):
        if not isinstance(row, dict):
            continue
        label = _row_label(row, i)
        for name, hib in _RATIO_METRICS.items():
            if row.get(name) is not None:
                out[f"{label}.{name}"] = (float(row[name]), hib)
        dense = row.get("dense_peak_elems")
        fused = row.get("fused_peak_elems")
        if dense and fused is not None:
            out[f"{label}.fused_over_dense_peak"] = (fused / dense, False)
    return out


def schema_of(payload):
    """The shape the schema-drift check pins: top-level keys, ``mode``,
    and the sorted set of per-row key tuples."""
    rows = payload.get("rows", [])
    return {
        "top_keys": sorted(payload.keys()),
        "mode": payload.get("mode"),
        "row_keys": sorted(
            {tuple(sorted(r.keys())) for r in rows if isinstance(r, dict)}
        ),
    }


def compare(current: dict, baseline: dict, name: str):
    """Failure strings for one BENCH file pair (empty = pass)."""
    fails = []
    cs, bs = schema_of(current), schema_of(baseline)
    if cs != bs:
        fails.append(
            f"{name}: schema drift — baseline {bs} vs current {cs}"
        )
        return fails  # metric names are meaningless once the schema moved
    cur_m, base_m = extract_metrics(current), extract_metrics(baseline)
    for key, (bval, hib) in base_m.items():
        if key not in cur_m:
            fails.append(f"{name}: metric {key} disappeared")
            continue
        cval, _ = cur_m[key]
        if bval == 0:
            # No percentage drift off a zero baseline — but a
            # lower-is-better metric that was zero must STAY zero
            # (e.g. serve-path recompiles).
            if not hib and cval > 0:
                fails.append(
                    f"{name}: {key} grew from a zero baseline "
                    f"(baseline 0 -> current {cval:.4f})"
                )
            continue
        change = (cval - bval) / abs(bval)
        bad = -change if hib else change
        if bad > THRESHOLD:
            direction = "dropped" if hib else "grew"
            fails.append(
                f"{name}: {key} {direction} {bad:.0%} "
                f"(baseline {bval:.4f} -> current {cval:.4f}, "
                f"threshold {THRESHOLD:.0%})"
            )
    return fails


def run_check(current_dir, baselines_dir, update=False):
    current_dir = pathlib.Path(current_dir)
    baselines_dir = pathlib.Path(baselines_dir)
    cur_files = sorted(current_dir.glob("BENCH_*.json"))
    base_files = sorted(baselines_dir.glob("BENCH_*.json"))

    if update:
        baselines_dir.mkdir(parents=True, exist_ok=True)
        for f in cur_files:
            shutil.copy(f, baselines_dir / f.name)
            print(f"snapshot {f.name} -> {baselines_dir}/")
        return 0

    fails, notes = [], []
    cur_names = {f.name for f in cur_files}
    for bf in base_files:
        if bf.name not in cur_names:
            fails.append(f"{bf.name}: baseline exists but current run "
                         f"produced no such file (bench dropped from CI?)")
    for cf in cur_files:
        bf = baselines_dir / cf.name
        if not bf.exists():
            notes.append(f"{cf.name}: no baseline yet (run --update to gate)")
            continue
        with open(cf) as fh:
            current = json.load(fh)
        with open(bf) as fh:
            baseline = json.load(fh)
        file_fails = compare(current, baseline, cf.name)
        if file_fails:
            fails.extend(file_fails)
        else:
            n = len(extract_metrics(baseline))
            print(f"{cf.name}: OK ({n} gated metrics, schema stable)")
    for n in notes:
        print(f"note: {n}")
    if fails:
        for f in fails:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default=".",
                    help="directory holding this run's BENCH_*.json")
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed baseline snapshots")
    ap.add_argument("--update", action="store_true",
                    help="snapshot current files as the new baselines")
    args = ap.parse_args()
    sys.exit(run_check(args.current, args.baselines, update=args.update))


if __name__ == "__main__":
    main()
