"""§Perf hillclimb driver — builds named VARIANTS of the three chosen
cells (different SCE distribution mode, microbatching, serving sharding),
lowers + compiles each, and records the roofline terms so the
hypothesis → change → measure → validate log in EXPERIMENTS.md §Perf is
reproducible.

  PYTHONPATH=src python -m benchmarks.perf_sweep --cell gemma2_sce
  PYTHONPATH=src python -m benchmarks.perf_sweep --all
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

PERF_DIR = os.path.join("results", "perf")

# variant grids for the three hillclimbed cells --------------------------------
SWEEPS = {
    # 1. paper-representative: SCE distribution strategy on the biggest
    #    dense vocab (gemma2, 256k items)
    "gemma2_sce": [
        ("gspmd_paper_literal", "gemma2-2b", "train_4k",
         {"sce_mode": "gspmd"}),
        ("exact_two_stage", "gemma2-2b", "train_4k",
         {"sce_mode": "exact"}),
        ("union_fused", "gemma2-2b", "train_4k",
         {"sce_mode": "union"}),
        ("union_by2048", "gemma2-2b", "train_4k",
         {"sce_mode": "union", "bucket_size_y": 2048}),
    ],
    # 2. most collective-bound: deepseek prefill — drop FSDP weight
    #    gathers on the serving path when TP-resident params fit
    "deepseek_prefill": [
        ("fsdp_weights_gathered", "deepseek-coder-33b", "prefill_32k",
         {"serve_fsdp_threshold": 0}),
        ("tp_resident_weights", "deepseek-coder-33b", "prefill_32k",
         {"serve_fsdp_threshold": 8e9}),
        ("seq_parallel", "deepseek-coder-33b", "prefill_32k",
         {"serve_fsdp_threshold": 0, "seq_parallel": True}),
        ("seq_parallel_tp_resident", "deepseek-coder-33b", "prefill_32k",
         {"serve_fsdp_threshold": 8e9, "seq_parallel": True}),
    ],
    # 3. worst roofline fraction at scale: kimi-k2 train — expert-weight
    #    HBM traffic vs activation memory via the microbatch knob
    "kimi_microbatch": [
        ("micro16", "kimi-k2-1t-a32b", "train_4k", {"n_micro": 16}),
        ("micro8", "kimi-k2-1t-a32b", "train_4k", {"n_micro": 8}),
        ("micro4", "kimi-k2-1t-a32b", "train_4k", {"n_micro": 4}),
    ],
}


def run_variant(name, arch, shape, opts, mesh_kind="single"):
    from repro.launch.cells import build_cell
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, **opts)
    compiled = cell.lower().compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text(), mesh.size)
    mult = cell.meta.get("loop_multiplier", 1)
    rec = {
        "variant": name,
        "arch": arch,
        "shape": shape,
        "opts": {k: v for k, v in opts.items()},
        "loop_multiplier": mult,
        "peak_gib": (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
        / 2**30,
        "flops_raw": cost.get("flops"),
        "bytes_raw": cost.get("bytes accessed"),
        "wire_bytes_raw": coll["total_bytes"],
        "wire_per_op": coll["per_op_bytes"],
        "coll_counts": coll["counts"],
        "compile_s": round(time.time() - t0, 1),
        "meta": cell.meta,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(SWEEPS))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    targets = sorted(SWEEPS) if args.all else [args.cell]

    os.makedirs(PERF_DIR, exist_ok=True)
    for sweep in targets:
        for name, arch, shape, opts in SWEEPS[sweep]:
            try:
                rec = run_variant(name, arch, shape, opts)
            except Exception as e:  # noqa: BLE001
                print(f"[FAIL] {sweep}/{name}: {e!r}")
                continue
            path = os.path.join(PERF_DIR, f"{sweep}__{name}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"[ok] {sweep}/{name}: peak={rec['peak_gib']:.2f} GiB "
                f"wire={rec['wire_bytes_raw']/2**20:.0f} MiB(raw) "
                f"flops={rec['flops_raw']:.3g}(raw) ×{rec['loop_multiplier']} "
                f"({rec['compile_s']}s)"
            )


if __name__ == "__main__":
    main()
