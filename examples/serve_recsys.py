"""Retrieval-server example: load (or init) a SASRec model and serve
top-k recommendations two ways — a synchronous bulk sweep and an async
burst through the bounded queue + bucket router — all on ahead-of-time
compiled shape-bucket programs (zero recompiles on the request path;
the MIPS streaming kernel scores the catalog, never a (B, C) matrix).

  PYTHONPATH=src python examples/serve_recsys.py --requests 128
  PYTHONPATH=src python examples/serve_recsys.py --ckpt-dir results/ckpt
"""
import argparse
import time

import numpy as np

from repro.data import Cursor, SeqDataConfig, SequenceDataset
from repro.launch.serve import RetrievalServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--buckets", default="8,32",
                    help="static batch-shape buckets (comma list)")
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir (omit = random-init smoke params)")
    args = ap.parse_args()

    buckets = tuple(int(b) for b in args.buckets.split(","))
    server = RetrievalServer(
        "sasrec-sce", buckets=buckets, top_k=args.top_k,
        queue_size=max(64, 2 * args.requests), ckpt_dir=args.ckpt_dir,
    )
    data = SequenceDataset(SeqDataConfig(
        n_items=server.cfg.n_items,
        seq_len=server.cfg.max_len,
        batch_size=args.requests,
    ))
    batch, _ = data.next_batch(Cursor(seed=42))
    histories = batch["tokens"]

    # --- bulk path: route → pad to buckets → AOT programs -------------
    t0 = time.time()
    vals, ids = server.score(histories)
    dt = time.time() - t0
    print(f"bulk: {args.requests} requests in {dt*1e3:.1f} ms "
          f"({args.requests/dt:.0f} req/s; buckets={server.router.buckets}, "
          f"catalog={server.cfg.n_items}, "
          f"recompiles={server.cache_misses})")

    # --- async path: burst through the bounded queue ------------------
    reqs = [server.submit(h) for h in histories]
    results = [r.result(timeout=120.0) for r in reqs]
    lats = sorted(r.latency_ms for r in reqs)
    print(f"async: p50 {lats[len(lats) // 2]:.2f} ms, "
          f"p99 {lats[min(len(lats) - 1, int(len(lats) * 0.99))]:.2f} ms "
          f"(degraded {server.degraded_served}, "
          f"rejected {server.rejected})")

    for u in range(3):
        print(f"user {u}: history tail {histories[u][-5:].tolist()} → "
              f"top-{args.top_k} {ids[u].tolist()}")
    # sanity: no padding id, no phantom rows, async == bulk, no
    # duplicates within a user's top-k, zero recompiles end to end
    assert (ids > 0).all() and (ids < server.cfg.n_items).all()
    assert all(len(np.unique(row)) == args.top_k for row in ids)
    assert all(
        np.array_equal(results[u].ids, ids[u][: results[u].k])
        for u in range(args.requests)
    )
    assert server.cache_misses == 0
    server.close()


if __name__ == "__main__":
    main()
