"""Batched serving example: load (or init) a SASRec model and serve
top-k recommendations for a stream of user histories through the
fixed-shape compiled scorer (no recompiles on the request path).

  PYTHONPATH=src python examples/serve_recsys.py --requests 128
"""
import argparse
import time

import numpy as np

from repro.data import Cursor, SeqDataConfig, SequenceDataset
from repro.launch.serve import RecsysServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=10)
    args = ap.parse_args()

    server = RecsysServer(
        "sasrec-sce", batch_size=args.batch_size, top_k=args.top_k
    )
    data = SequenceDataset(SeqDataConfig(
        n_items=server.cfg.n_items,
        seq_len=server.cfg.max_len,
        batch_size=args.requests,
    ))
    batch, _ = data.next_batch(Cursor(seed=42))
    histories = batch["tokens"]

    # warmup compile, then measure steady-state latency
    server.score(histories[: args.batch_size])
    t0 = time.time()
    vals, ids = server.score(histories)
    dt = time.time() - t0

    print(f"{args.requests} requests in {dt*1e3:.1f} ms "
          f"({args.requests/dt:.0f} req/s; batch={args.batch_size}, "
          f"catalog={server.cfg.n_items})")
    for u in range(3):
        print(f"user {u}: history tail {histories[u][-5:].tolist()} → "
              f"top-{args.top_k} {ids[u].tolist()}")
    # sanity: no padding id, no duplicates within a user's top-k
    assert (ids > 0).all()
    assert all(len(np.unique(row)) == args.top_k for row in ids)


if __name__ == "__main__":
    main()
