"""End-to-end driver: train SASRec with the SCE loss on the synthetic
Zipf-cluster catalog, with checkpoint/restart and unsampled evaluation —
the paper's SASRec-SCE setup as a runnable script.

A few hundred steps on CPU reach a clearly-above-popularity NDCG@10 on
held-out users; pass --items/--steps/--batch to scale up.

  PYTHONPATH=src python examples/train_sasrec_sce.py --steps 300
  # kill it mid-run and re-run: it resumes from the last checkpoint
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.sce import SCEConfig, sce_loss
from repro.eval import evaluate_streaming
from repro.data import Cursor, SeqDataConfig, SequenceDataset
from repro.models import sasrec
from repro.optim import linear_warmup_cosine, make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=5000)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--b-y", type=int, default=128)
    ap.add_argument("--no-mix", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/sasrec_sce_ckpt")
    ap.add_argument("--eval-every", type=int, default=100)
    args = ap.parse_args()

    cfg = sasrec.SeqRecConfig(
        n_items=args.items, max_len=args.seq_len, d_model=args.d_model,
        n_layers=2, n_heads=2, dropout=0.0,
    )
    sce_cfg = SCEConfig.from_alpha_beta(
        args.batch * args.seq_len, args.items,
        bucket_size_y=args.b_y, use_mix=not args.no_mix,
    )
    print(f"SASRec-SCE: C={args.items} params={cfg.param_count():,} "
          f"SCE(n_b={sce_cfg.n_buckets}, b_x={sce_cfg.bucket_size_x}, "
          f"b_y={sce_cfg.bucket_size_y}, mix={sce_cfg.use_mix})")

    data = SequenceDataset(SeqDataConfig(
        n_items=args.items, seq_len=args.seq_len, batch_size=args.batch,
    ))
    sched = linear_warmup_cosine(1e-3, 20, args.steps)
    opt_init, opt_update = make_optimizer("adamw", sched)

    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_init(params)
    cursor, key, start = Cursor(seed=0), jax.random.PRNGKey(1), 0

    mgr = CheckpointManager(args.ckpt_dir, keep_n=2)
    last, state = mgr.restore_latest()
    if last is not None:
        params, key = state["params"], state["key"]
        opt_state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(opt_state),
            jax.tree_util.tree_leaves(state["opt_state"]),
        )
        cursor = Cursor.from_state(state["cursor"])
        start = int(state["step"]) + 1
        print(f"resumed from checkpoint at step {last}")

    @jax.jit
    def train_step(params, opt_state, tokens, targets, valid, key):
        def loss_fn(p):
            hidden = sasrec.forward(p, cfg, tokens)
            return sce_loss(
                hidden.reshape(-1, cfg.d_model),
                sasrec.loss_catalog(p, cfg),
                targets.reshape(-1),
                key=key, cfg=sce_cfg, valid_mask=valid.reshape(-1),
            )
        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, loss

    eval_data = SequenceDataset(SeqDataConfig(
        n_items=args.items, seq_len=args.seq_len, batch_size=512,
    ))

    t0 = time.time()
    for step in range(start, args.steps):
        batch, cursor = data.next_batch(cursor)
        key, k = jax.random.split(key)
        params, opt_state, loss = train_step(
            params, opt_state,
            jnp.asarray(batch["tokens"]), jnp.asarray(batch["targets"]),
            jnp.asarray(batch["valid"]), k,
        )
        if step % 25 == 0:
            print(f"step {step:4d}  sce-loss {float(loss):.4f}")
        if (step + 1) % args.eval_every == 0 or step == args.steps - 1:
            eb, _ = eval_data.eval_batch(Cursor(seed=0))
            # streaming unsampled metrics — no (B, C) score matrix
            m = evaluate_streaming(params, cfg, eb)
            print(f"  eval: NDCG@10 {m['ndcg@10']:.4f}  "
                  f"HR@10 {m['hr@10']:.4f}  COV@10 {m['cov@10']:.4f}")
            mgr.save(step, {
                "params": params, "opt_state": opt_state,
                "key": key, "cursor": cursor.to_state(), "step": step,
            }, blocking=False)
    mgr.wait()
    print(f"done in {time.time()-t0:.0f}s — checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
