"""Quickstart — the Scalable Cross-Entropy loss in 60 lines.

Builds a toy catalog problem, computes full CE and SCE, shows that the
exactness limit recovers CE bit-for-bit, and prints the memory model
that is the paper's whole point.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    SCEConfig,
    full_ce_memory_bytes,
    make_loss,
    sce_loss,
    sce_loss_memory_bytes,
)

# -- a toy "catalog" problem -------------------------------------------------
N, C, D = 512, 10_000, 64  # positions (batch·seq), catalog, width
key = jax.random.PRNGKey(0)
kx, ky, kt, kb = jax.random.split(key, 4)
x = jax.random.normal(kx, (N, D))  # model outputs
y = jax.random.normal(ky, (C, D))  # item embeddings
targets = jax.random.randint(kt, (N,), 0, C)

# -- full CE (the memory hog) -------------------------------------------------
ce = make_loss("ce")
ce_val, _ = ce(x, y, targets)
print(f"full CE            : {float(ce_val):.4f}")

# -- SCE (paper Algorithm 1 + Mix) --------------------------------------------
cfg = SCEConfig.from_alpha_beta(N, C, alpha=2.0, beta=1.0,
                                bucket_size_y=256)
sce_val = sce_loss(x, y, targets, key=kb, cfg=cfg)
print(f"SCE (α=2, β=1)     : {float(sce_val):.4f}   "
      f"n_b={cfg.n_buckets} b_x={cfg.bucket_size_x} b_y={cfg.bucket_size_y}")

# -- the exactness limit: one bucket covering everything == CE ---------------
exact_cfg = SCEConfig(n_buckets=1, bucket_size_x=N, bucket_size_y=C)
exact = sce_loss(x, y, targets, key=kb, cfg=exact_cfg)
print(f"SCE exactness limit: {float(exact):.4f}   (== CE)")
assert abs(float(exact) - float(ce_val)) < 1e-4

# -- the memory story ----------------------------------------------------------
ce_bytes = full_ce_memory_bytes(N, C)
sce_bytes = sce_loss_memory_bytes(cfg)
print(f"\nlogit-tensor memory: CE {ce_bytes/2**20:.0f} MiB  "
      f"vs SCE {sce_bytes/2**20:.1f} MiB  "
      f"({ce_bytes/sce_bytes:.0f}x smaller)")
print("at the paper's example (s=128, l=200, C=10^6):",
      f"CE {full_ce_memory_bytes(128*200, 10**6)/2**30:.0f} GiB vs",
      f"SCE {sce_loss_memory_bytes(SCEConfig.from_alpha_beta(128*200, 10**6, bucket_size_y=256))/2**20:.0f} MiB")

# -- gradients flow through the selected logits only ---------------------------
grads = jax.grad(lambda x: sce_loss(x, y, targets, key=kb, cfg=cfg))(x)
print(f"\ngrad sparsity: {float(jnp.mean(jnp.all(grads == 0, axis=-1))):.1%} "
      f"of positions untouched this step (uncovered by any bucket)")
