"""Distributed-SCE demo on 8 simulated devices.

Shards the model outputs over a ``data`` axis and the item catalog over a
``model`` axis (vocab-parallel), runs both distributed SCE modes, and
checks them against the single-device oracle — the same code path the
512-chip dry-run lowers.

  PYTHONPATH=src python examples/distributed_sce_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.distributed_sce import (  # noqa: E402
    sce_loss_sharded,
    sce_loss_sharded_ref,
)
from repro.core.sce import SCEConfig  # noqa: E402
from repro.dist import make_mesh, set_mesh  # noqa: E402


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    key = jax.random.PRNGKey(0)
    N, C, d = 1024, 4096, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (N, d))
    y = jax.random.normal(jax.random.PRNGKey(2), (C, d)) * 0.5
    t = jax.random.randint(jax.random.PRNGKey(3), (N,), 0, C)
    cfg = SCEConfig.from_alpha_beta(N // 2, C, bucket_size_y=128)
    print(f"SCE: n_b={cfg.n_buckets} b_x={cfg.bucket_size_x} "
          f"b_y={cfg.bucket_size_y} (per data shard)")

    for mode in ("exact", "union"):
        with set_mesh(mesh):
            loss = jax.jit(
                lambda x, y: sce_loss_sharded(
                    x, y, t, key=key, cfg=cfg, mesh=mesh, mode=mode
                )
            )(x, y)
        ref = sce_loss_sharded_ref(
            x, y, t, key=key, cfg=cfg, dp_size=2, mode=mode, tp_size=4
        )
        np.testing.assert_allclose(loss, ref, rtol=1e-5)
        print(f"mode={mode:5s}: distributed {float(loss):.5f} == "
              f"single-device oracle {float(ref):.5f}  ✓")

    print("\nwhat moved over the wire (per step, per device):")
    print("  exact : 2 all-gathers of (value, global-id) candidate pairs")
    print("          + 1 psum of (n_b, b_x) partial-LSE merges")
    print("  union : 1 psum of (n_b, b_x) partial (max,sumexp) — ~KBs")
    print("  candidate embeddings never leave their shard in either mode")


if __name__ == "__main__":
    main()
